"""Distributed runtime tests: sharding rules, compression, fault tolerance.

Multi-device behaviour (8 fake CPU devices) runs in a subprocess so the main
test process keeps its single-device view.
"""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.distributed.collectives import (
    compress_gradients_topk,
    compression_ratio,
    dequantize_int8,
    init_error_feedback,
    quantize_int8,
    topk_compress,
    topk_decompress,
)
from repro.distributed.fault_tolerance import (
    RecoveryPlan,
    degraded_mesh_plan,
    expansion_mesh_plan,
    straggler_policy,
)


# ---------------------------------------------------------------------------
# Sharding rules (structural: specs valid for every arch without devices)
# ---------------------------------------------------------------------------

def _fake_mesh_shapes():
    """AbstractMesh stand-in: rule functions only read .shape/.axis_names."""
    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}
    return FakeMesh()


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_specs_cover_all_leaves(arch):
    from repro.distributed.sharding import param_spec
    from repro.models import build_model

    cfg = get_config(arch)
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    mesh = _fake_mesh_shapes()
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    n_model_sharded = 0
    for path, leaf in flat:
        pstr = "/".join(str(getattr(p, "key", p)) for p in path)
        spec = param_spec(pstr, leaf.shape, mesh)
        assert len(spec) <= len(leaf.shape)
        # every named axis must divide its dim
        for dim, s in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if s is None:
                continue
            axes = (s,) if isinstance(s, str) else s
            ways = 1
            for a in axes:
                ways *= mesh.shape[a]
            assert dim % ways == 0, (arch, pstr, leaf.shape, spec)
        if "model" in str(spec):
            n_model_sharded += 1
    assert n_model_sharded > 0, f"{arch}: nothing is tensor-parallel"


@pytest.mark.parametrize("arch", ["arctic-480b", "moonshot-v1-16b-a3b"])
def test_moe_experts_sharded_over_model(arch):
    from repro.distributed.sharding import param_spec

    cfg = get_config(arch)
    mesh = _fake_mesh_shapes()
    E, d, f = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    spec = param_spec("blocks/moe/w_gate", (47, E, d, f), mesh)
    assert tuple(spec)[1] == "model"  # expert-parallel


def test_per_chip_param_bytes_fit_hbm():
    """480B-class training state must fit 16GB/chip under the rules."""
    from repro.distributed.sharding import param_spec
    from repro.models import build_model

    cfg = get_config("arctic-480b")
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    mesh = _fake_mesh_shapes()
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    per_chip = 0.0
    for path, leaf in flat:
        pstr = "/".join(str(getattr(p, "key", p)) for p in path)
        spec = param_spec(pstr, leaf.shape, mesh)
        ways = 1
        for s in spec:
            if s is None:
                continue
            axes = (s,) if isinstance(s, str) else s
            for a in axes:
                ways *= mesh.shape[a]
        per_chip += np.prod(leaf.shape) / ways
    # bf16 params + bf16 moments (arctic dry-run optimizer) = 6 bytes/param
    assert per_chip * 6 < 16e9, f"{per_chip * 6 / 1e9:.1f} GB/chip"


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------

def test_topk_roundtrip_preserves_big_entries():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(64, 32)))
    idx, vals, residual = topk_compress(x, 0.1)
    dec = topk_decompress(idx, vals, x.shape)
    flat = np.abs(np.asarray(x)).ravel()
    thresh = np.sort(flat)[-int(flat.size * 0.1)]
    big = np.abs(np.asarray(x)) >= thresh
    np.testing.assert_allclose(np.asarray(dec)[big], np.asarray(x)[big],
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(dec + residual), np.asarray(x),
                               rtol=1e-6)


def test_error_feedback_accumulates():
    """With error feedback, repeated compression of a CONSTANT gradient must
    pass the full magnitude through over time (no systematic bias)."""
    g = {"w": jnp.asarray(np.random.default_rng(1).normal(size=(256,)))}
    ef = init_error_feedback(g)
    total = jnp.zeros_like(g["w"])
    n = 200  # ≫ rotation period 1/frac = 20 so the EF bias averages out
    for _ in range(n):
        comp, ef, effective = compress_gradients_topk(g, ef, 0.05)
        total = total + effective["w"]
    # mean transmitted per step -> g as steps grow
    np.testing.assert_allclose(np.asarray(total / n), np.asarray(g["w"]),
                               atol=0.12)


def test_compression_ratio():
    g = {"w": jnp.ones((1000,))}
    ef = init_error_feedback(g)
    comp, _, _ = compress_gradients_topk(g, ef, 0.01)
    assert compression_ratio(comp) < 0.05


def test_int8_quantization_error_bounded():
    x = jnp.asarray(np.random.default_rng(2).normal(size=(4096,)))
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale) - x))
    assert err.max() <= float(scale) * 0.5 + 1e-7


def test_int8_allreduce_multidevice_subprocess():
    """Real shard_map int8 all-reduce on 8 fake devices."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        import sys; sys.path.insert(0, "src")
        from repro.distributed.collectives import make_compressed_allreduce
        mesh = jax.make_mesh((8,), ("data",))
        fn = make_compressed_allreduce(mesh, "data")
        x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 128)))
        got = fn(x)
        want = np.mean(np.asarray(x), axis=0)
        np.testing.assert_allclose(np.asarray(got), want, atol=0.05)
        print("OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=".", timeout=300)
    assert "OK" in out.stdout, out.stderr[-2000:]


# ---------------------------------------------------------------------------
# Fault tolerance / elasticity
# ---------------------------------------------------------------------------

def test_degraded_mesh_drops_data_rows():
    plan = degraded_mesh_plan((2, 16, 16), ("pod", "data", "model"),
                              failed_chips=3, chips_per_host=4)
    assert plan.shape == (2, 15, 16)
    assert plan.batch_scale == pytest.approx(16 / 15)


def test_degraded_mesh_multiple_hosts():
    plan = degraded_mesh_plan((16, 16), ("data", "model"), failed_chips=40,
                              chips_per_host=4)
    assert plan.shape == (13, 16)


def test_degraded_mesh_unrecoverable():
    with pytest.raises(RuntimeError):
        degraded_mesh_plan((2, 16), ("data", "model"), failed_chips=64,
                           chips_per_host=4)


def test_expansion_plan():
    plan = expansion_mesh_plan((14, 16), ("data", "model"), new_chips=32)
    assert plan.shape == (16, 16)


def test_recovery_plan_uses_latest_checkpoint():
    plan = degraded_mesh_plan((16, 16), ("data", "model"), 4)
    rec = RecoveryPlan.build(plan, [100, 300, 200])
    assert rec.restore_step == 300
    assert rec.resume_data_step == 300


def test_straggler_detection():
    times = np.ones((8, 10)) * 0.1
    times[3] *= 5.0                         # persistent straggler
    out = straggler_policy(times)
    assert list(out["stragglers"]) == [3]
    assert out["action"] == "drain-and-redistribute"
    # a single slow step is NOT a straggler
    times2 = np.ones((8, 10)) * 0.1
    times2[2, 4] = 3.0
    assert len(straggler_policy(times2)["stragglers"]) == 0


def test_elastic_resharding_subprocess():
    """Shrink 8->6 devices: params restored from checkpoint re-shard onto the
    degraded mesh and a jitted matmul still runs."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        import sys; sys.path.insert(0, "src")
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.distributed.fault_tolerance import degraded_mesh_plan

        w = np.arange(48.0, dtype=np.float32).reshape(8, 6)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        sharded = jax.device_put(w, NamedSharding(mesh, P("data", "model")))
        plan = degraded_mesh_plan((4, 2), ("data", "model"), failed_chips=2,
                                  chips_per_host=2)
        assert plan.shape == (3, 2), plan.shape
        new_mesh = jax.make_mesh(plan.shape, plan.axis_names,
                                 devices=np.array(jax.devices()[:6]))
        # checkpoint-restore path: host roundtrip then re-place
        host = np.asarray(sharded)
        resharded = jax.device_put(host, NamedSharding(new_mesh, P(None, "model")))
        y = jax.jit(lambda a: (a @ a.T).sum())(resharded)
        np.testing.assert_allclose(float(y), float((w @ w.T).sum()), rtol=1e-6)
        print("OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=".", timeout=300)
    assert "OK" in out.stdout, out.stderr[-2000:]
