"""Serving runtime tests: engine, scheduler, cache utilities."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import cache_bytes, needs_state_rollback
from repro.serving.scheduler import Request, RoundScheduler


def test_engine_generate_matches_incremental_scoring():
    """AR generation with cache must equal argmax over full re-scoring."""
    cfg = get_config("deepseek-7b").smoke()
    eng = ServingEngine(cfg, max_len=64)
    eng.init_params(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, cfg.vocab_size)
    out = eng.generate(prompts, 8, jax.random.PRNGKey(2), temperature=0.0)
    # greedy reference without cache
    for b in range(2):
        seq = list(np.asarray(prompts[b]))
        for _ in range(8):
            logits, _ = eng.model.apply(eng.params, jnp.asarray(seq)[None])
            seq.append(int(jnp.argmax(logits[0, -1])))
        assert seq == out[b]


def test_scheduler_admission_and_retirement():
    sched = RoundScheduler(max_batch=3)
    for i in range(5):
        sched.submit(Request(rid=i, prompt_len=8, max_new_tokens=10))
    active = sched.admit()
    assert len(active) == 3
    # round 1: everyone gets 4 tokens
    sched.complete_round(np.array([4, 4, 4]), round_time=0.5)
    assert len(sched.active) == 3
    # round 2: 6+ tokens retire all three, queue refills
    sched.complete_round(np.array([8, 8, 8]), round_time=0.5)
    assert sched.stats.completed == 3
    active = sched.admit()
    assert len(active) == 2
    assert sched.stats.total_tokens == 3 * 10  # capped at max_new_tokens


def test_scheduler_goodput_accounting():
    sched = RoundScheduler(max_batch=2)
    for i in range(2):
        sched.submit(Request(rid=i, prompt_len=4, max_new_tokens=6))
    sched.admit()
    sched.complete_round(np.array([3, 3]), 1.0)
    sched.complete_round(np.array([3, 3]), 1.0)
    assert sched.idle
    assert sched.stats.goodput == pytest.approx(6.0)


def test_cache_utilities():
    cfg = get_config("zamba2-2.7b").smoke()
    assert needs_state_rollback(cfg)
    assert not needs_state_rollback(get_config("gemma-7b").smoke())
    from repro.models import build_model
    cache = build_model(cfg).init_cache(2, 16, jnp.float32)
    assert cache_bytes(cache) > 0
