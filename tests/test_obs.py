"""Observability subsystem: span tracer semantics, kernel dispatch spans,
cell/engine span nesting, the gateway ``/v1/trace`` endpoint, and the BENCH
regression gate.

Async tests run through ``asyncio.run`` inside sync test functions (no
pytest-asyncio dependency).
"""

import asyncio
import json
import re
import threading

import pytest

from repro.api import CellConfig, MultiSpinCell, Request
from repro.obs import trace
from repro.serving.gateway import (
    GatewayClient,
    GatewayConfig,
    GatewayError,
    MultiSpinGateway,
)


def _cell(seed=0, max_batch=8, **kw):
    cfg = CellConfig(scheme="hete", max_batch=max_batch, seed=seed,
                     t_ver_fix=0.035, t_ver_lin=0.0177, L_max=8, **kw)
    return MultiSpinCell(cfg)


async def _start(cell, **gw_kw):
    gw = MultiSpinGateway(cell, GatewayConfig(port=0, idle_wait_s=0.02,
                                              **gw_kw))
    await gw.start()
    return gw, GatewayClient(port=gw.port)


# ---------------------------------------------------------------------------
# disabled tracing is free: the shared null singleton
# ---------------------------------------------------------------------------

def test_disabled_tracing_returns_the_null_singleton():
    """With no tracer installed every span() call returns the SAME object
    (the module singleton) — no per-call allocation — and the args-dict
    guard in the kernel dispatch helper short-circuits too."""
    assert trace.active() is None
    sp = trace.span("anything", cat="x", args={"k": 1})
    assert sp is trace.NULL_SPAN
    assert trace.span("other") is sp          # identity, not equality
    with sp as inner:                          # usable as a context manager
        inner.set(a=1)
        inner.attach(object())

    import jax.numpy as jnp

    from repro.kernels import ops
    assert ops._span("ops.x", jnp.zeros((2, 2))) is trace.NULL_SPAN


def test_tracing_scope_restores_previous_state():
    assert trace.active() is None
    with trace.tracing() as tr:
        assert trace.active() is tr
        with trace.tracing() as inner:
            assert trace.active() is inner
        assert trace.active() is tr
    assert trace.active() is None


# ---------------------------------------------------------------------------
# nesting, args, thread isolation, ring bound
# ---------------------------------------------------------------------------

def test_nested_spans_record_parent_links():
    with trace.tracing() as tr:
        with trace.span("outer", cat="t", args={"a": 1}) as outer:
            with trace.span("inner", cat="t") as inner:
                pass
            outer.set(b=2)
        with trace.span("sibling") as sib:
            pass
    spans = {sp.name: sp for sp in tr.snapshot()}
    assert set(spans) == {"outer", "inner", "sibling"}
    assert spans["inner"].parent_sid == spans["outer"].sid
    assert spans["outer"].parent_sid == -1
    assert spans["sibling"].parent_sid == -1
    assert len({sp.sid for sp in spans.values()}) == 3
    assert all(sp.dur_ns >= 0 for sp in spans.values())
    assert spans["outer"].args == {"a": 1, "b": 2}
    # exit order: inner closes before outer
    assert [sp.name for sp in tr.snapshot()] == ["inner", "outer", "sibling"]


def test_thread_local_stacks_never_cross_parent_links():
    """Each thread keeps its own span stack: a child's parent is always a
    span opened on the SAME thread, even under concurrent nesting."""
    tracer = trace.Tracer()
    n_threads, n_iter = 4, 25
    barrier = threading.Barrier(n_threads)

    def work(idx):
        barrier.wait()
        for _ in range(n_iter):
            with tracer.span(f"outer-{idx}"):
                with tracer.span(f"inner-{idx}"):
                    pass

    threads = [threading.Thread(target=work, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    spans = tracer.snapshot()
    assert len(spans) == n_threads * n_iter * 2
    by_sid = {sp.sid: sp for sp in spans}
    for sp in spans:
        if not sp.name.startswith("inner-"):
            continue
        parent = by_sid[sp.parent_sid]
        assert parent.tid == sp.tid
        assert parent.name == sp.name.replace("inner", "outer")


def test_ring_is_bounded_and_counts_drops():
    tracer = trace.Tracer(capacity=8)
    with trace.tracing(tracer):
        for i in range(20):
            with trace.span(f"s{i}"):
                pass
    spans = tracer.snapshot()
    assert len(spans) == 8
    assert [sp.name for sp in spans] == [f"s{i}" for i in range(12, 20)]
    assert tracer.dropped == 12
    assert tracer.export_chrome_trace()["otherData"]["dropped_spans"] == 12
    tracer.clear()
    assert tracer.snapshot() == [] and tracer.dropped == 0


def test_totals_aggregate_matches_snapshot():
    with trace.tracing() as tr:
        for _ in range(3):
            with trace.span("a"):
                pass
        for _ in range(2):
            with trace.span("b"):
                pass
    totals = tr.totals()
    assert totals["a"]["count"] == 3 and totals["b"]["count"] == 2
    want = sum(sp.dur_ns for sp in tr.snapshot() if sp.name == "a") * 1e-9
    assert totals["a"]["seconds"] == pytest.approx(want)


# ---------------------------------------------------------------------------
# Chrome trace-event export
# ---------------------------------------------------------------------------

def test_chrome_trace_export_is_valid_trace_event_json():
    with trace.tracing() as tr:
        with trace.span("outer", cat="cell") as outer:
            with trace.span("inner", cat="kernel", args={"shape": [2, 2]}):
                pass
    text = tr.export_chrome_trace_json(process_name="test-proc")
    data = json.loads(text)                    # round-trips as strict JSON
    assert data["displayTimeUnit"] == "ms"
    events = data["traceEvents"]
    metas = [e for e in events if e["ph"] == "M"]
    xs = [e for e in events if e["ph"] == "X"]
    assert {e["ph"] for e in events} == {"M", "X"}
    assert any(e["name"] == "process_name"
               and e["args"]["name"] == "test-proc" for e in metas)
    assert any(e["name"] == "thread_name" for e in metas)
    for e in xs:
        assert isinstance(e["ts"], (int, float)) and e["ts"] >= 0
        assert isinstance(e["dur"], (int, float)) and e["dur"] >= 0
        assert e["pid"] == 1 and e["tid"] >= 1
        assert "sid" in e["args"]
    by_name = {e["name"]: e for e in xs}
    assert by_name["inner"]["args"]["parent_sid"] == \
        by_name["outer"]["args"]["sid"]
    assert by_name["inner"]["args"]["shape"] == [2, 2]
    assert outer.sid == by_name["outer"]["args"]["sid"]


# ---------------------------------------------------------------------------
# kernel dispatch spans (ops.*)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["ref", "interpret"])
def test_ops_dispatch_emits_named_spans(monkeypatch, mode):
    """Every public op opens an ``ops.<name>`` span recording the backend
    actually dispatched plus the lead operand's shape/dtype."""
    monkeypatch.setenv("REPRO_KERNELS", mode)
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    B, S, H, KV, D = 2, 64, 4, 2, 16
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(keys[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(keys[1], (B, S, KV, D), jnp.float32)
    v = jax.random.normal(keys[2], (B, S, KV, D), jnp.float32)
    with trace.tracing() as tr:
        out = ops.flash_attention(q, k, v)
    assert out.shape == q.shape
    spans = [sp for sp in tr.snapshot() if sp.name == "ops.flash_attention"]
    assert len(spans) == 1
    sp = spans[0]
    assert sp.cat == "kernel"
    assert sp.args["backend"] == mode
    assert sp.args["shape"] == [B, S, H, D]
    assert sp.args["dtype"] == "float32"


# ---------------------------------------------------------------------------
# cell instrumentation: step spans agree with summary()
# ---------------------------------------------------------------------------

def test_cell_step_spans_are_consistent_with_summary():
    cell = _cell(max_batch=4)
    for i, a in enumerate((0.71, 0.74, 0.86, 0.8)):
        cell.submit(Request(rid=i, prompt_len=8, max_new_tokens=16,
                            alpha=a, T_S=0.009))
    with trace.tracing() as tr:
        cell.run()
    spans = tr.snapshot()
    steps = [sp for sp in spans if sp.name == "cell.step"]
    assert len(steps) == len(cell.history) > 0
    for sp in steps:
        assert sp.args["scheme"] == "hete"
        assert sp.args["schedule"] == "sync"
        assert set(sp.args) >= {"round", "rids", "t_draft", "t_upload",
                                "t_ver", "t_round"}
    # the simulated phase seconds attached to spans ARE the summary numbers
    summary = cell.summary()
    assert sum(sp.args["t_draft"] for sp in steps) == \
        pytest.approx(summary["seconds_draft"])
    assert sum(sp.args["t_ver"] for sp in steps) == \
        pytest.approx(summary["seconds_verify"])
    # plan + verify spans nest under their round's step span
    step_sids = {sp.sid for sp in steps}
    for name in ("cell.plan", "cell.verify"):
        inner = [sp for sp in spans if sp.name == name]
        assert len(inner) == len(steps)
        assert all(sp.parent_sid in step_sids for sp in inner)


# ---------------------------------------------------------------------------
# gateway: /v1/trace + per-request trace ids
# ---------------------------------------------------------------------------

def test_gateway_trace_endpoint_and_stream_trace_ids():
    async def run():
        gw, cli = await _start(_cell(max_batch=2), trace_spans=True)
        ids = []
        async for ev in cli.stream_generate(prompt_len=8, max_new_tokens=8,
                                            alpha=0.8, T_S=0.009):
            assert "trace_id" in ev.data, ev.event
            ids.append(ev.data["trace_id"])
        data = await cli.trace()
        owned = gw._owns_tracer
        await gw.stop()
        return ids, data, owned

    ids, data, owned = asyncio.run(run())
    # queued/round/done all carry the SAME request-scoped trace id
    assert len(ids) >= 3 and len(set(ids)) == 1
    assert re.fullmatch(r"[0-9a-f]+-[0-9a-f]{12}", ids[0])
    # the exported trace is Chrome-trace shaped and contains the cell spans
    xs = [e for e in data["traceEvents"] if e.get("ph") == "X"]
    names = {e["name"] for e in xs}
    assert {"cell.step", "cell.plan", "cell.verify"} <= names
    step_sids = {e["args"]["sid"] for e in xs if e["name"] == "cell.step"}
    assert all(e["args"]["parent_sid"] in step_sids
               for e in xs if e["name"] == "cell.verify")
    # the gateway owned the tracer and uninstalled it on stop
    assert owned and trace.active() is None


def test_gateway_trace_disabled_returns_409():
    async def run():
        gw, cli = await _start(_cell(max_batch=2))     # tracing off
        try:
            with pytest.raises(GatewayError) as exc:
                await cli.trace()
        finally:
            await gw.stop()
        return exc.value

    err = asyncio.run(run())
    assert err.status == 409
    assert err.body["error"] == "tracing_disabled"


def test_gateway_reuses_an_already_installed_tracer():
    """A test/bench scoped tracer survives the gateway: the gateway records
    into it and must NOT uninstall it on stop."""
    async def run(cell):
        gw, cli = await _start(cell, trace_spans=True)
        await cli.generate(prompt_len=8, max_new_tokens=8,
                           alpha=0.8, T_S=0.009)
        owned = gw._owns_tracer
        await gw.stop()
        return owned

    with trace.tracing() as tr:
        owned = asyncio.run(run(_cell(max_batch=2)))
        assert not owned
        assert trace.active() is tr
        assert any(sp.name == "cell.step" for sp in tr.snapshot())
    assert trace.active() is None


# ---------------------------------------------------------------------------
# the full nesting chain on a REAL engine backend
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_engine_backend_nests_cell_engine_and_kernel_spans():
    """cell.step -> cell.verify -> engine.verify -> engine.* -> ops.* : the
    parent links walk all the way from a kernel dispatch span up to the
    round's cell.step span on a real paged SpecEngine."""
    import jax

    from repro.api import EngineBackend, SpecEngine
    from repro.configs import get_config

    tcfg = get_config("qwen2.5-3b").smoke()
    dcfg = tcfg.replace(num_layers=1, d_model=32, num_heads=2,
                        num_kv_heads=1, head_dim=16, d_ff=64,
                        name="draft-smoke")
    eng = SpecEngine(tcfg, dcfg, max_len=128, cache_kind="paged")
    eng.init_params(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 tcfg.vocab_size)
    backend = EngineBackend(eng, eng.start(prompts),
                            keep_finished_tokens=True)
    cell = MultiSpinCell(CellConfig(scheme="fixed", L_fixed=3, max_batch=2,
                                    seed=0), backend=backend)
    for i in range(2):
        cell.submit(Request(rid=i, prompt_len=8, max_new_tokens=8,
                            alpha=0.8, T_S=0.009))
    with trace.tracing() as tr:
        cell.run()

    spans = tr.snapshot()
    by_sid = {sp.sid: sp for sp in spans}
    names = {sp.name for sp in spans}
    assert {"cell.step", "cell.verify", "engine.verify"} <= names
    assert any(n.startswith("ops.") for n in names)

    def ancestors(sp):
        chain = []
        while sp.parent_sid >= 0:
            sp = by_sid[sp.parent_sid]
            chain.append(sp.name)
        return chain

    # every engine.verify span sits inside a cell.step
    for sp in spans:
        if sp.name == "engine.verify":
            assert "cell.step" in ancestors(sp)
    # and at least one kernel dispatch span has the FULL chain above it
    chains = [ancestors(sp) for sp in spans if sp.name.startswith("ops.")]
    assert any("engine.verify" in c and "cell.step" in c for c in chains)


# ---------------------------------------------------------------------------
# BENCH regression gate
# ---------------------------------------------------------------------------

def test_regression_gate_passes_on_committed_baselines(capsys):
    from benchmarks import regression
    assert regression.run() == 0
    assert "0 failure(s)" in capsys.readouterr().out


def test_regression_gate_fails_on_quality_regression(tmp_path):
    """A halved goodput in a fresh run must fail the gate even though the
    envelope hosts match (quality metrics always gate)."""
    import shutil

    from benchmarks import regression
    for fname in regression.BENCH_FILES:
        shutil.copy(str(regression.REPO_ROOT) + "/" + fname,
                    str(tmp_path / fname))
    churn = json.loads((tmp_path / "BENCH_churn.json").read_text())
    for row in churn["rows"]:
        if "goodput" in row:
            row["goodput"] *= 0.5
    (tmp_path / "BENCH_churn.json").write_text(json.dumps(churn))
    assert regression.run(fresh_dir=str(tmp_path)) > 0


def test_regression_gate_host_gating_for_timing_metrics(tmp_path):
    """Timing metrics gate same-host (or under --strict-timing) but only
    WARN cross-host; quality metrics are host-independent."""
    from benchmarks import regression

    def write(dirname, host, us):
        d = tmp_path / dirname
        d.mkdir(exist_ok=True)
        (d / "BENCH_kernels.json").write_text(json.dumps({
            "schema_version": 2, "host": host,
            "rows": [{"name": "kernels/x", "us_per_call": us}]}))
        return str(d)

    base = write("base", "host-a", 10.0)
    slow_other_host = write("other", "host-b", 100.0)
    slow_same_host = write("same", "host-a", 100.0)
    files = ("BENCH_kernels.json",)
    # cross-host 10x slowdown: informational only
    assert regression.run(base, slow_other_host, files=files) == 0
    # ... unless forced
    assert regression.run(base, slow_other_host, strict_timing=True,
                          files=files) > 0
    # same host: gates without any flag
    assert regression.run(base, slow_same_host, files=files) > 0


def test_regression_gate_fails_on_missing_rows_and_metrics(tmp_path):
    from benchmarks import regression

    def write(dirname, rows):
        d = tmp_path / dirname
        d.mkdir(exist_ok=True)
        (d / "BENCH_kernels.json").write_text(json.dumps({
            "schema_version": 2, "host": "h", "rows": rows}))
        return str(d)

    base = write("base", [{"name": "kernels/x", "goodput": 1.0}])
    files = ("BENCH_kernels.json",)
    # a metric that vanishes from the fresh rows is a failure
    no_metric = write("nm", [{"name": "kernels/x"}])
    assert regression.run(base, no_metric, files=files) > 0
    # a whole row that vanishes is a failure
    no_row = write("nr", [{"name": "kernels/y", "goodput": 1.0}])
    assert regression.run(base, no_row, files=files) > 0
    # a missing fresh file is a failure
    empty = tmp_path / "empty"
    empty.mkdir()
    assert regression.run(base, str(empty), files=files) > 0
