"""Integration tests: the real-model speculative engine + the cell-level
round protocol (ported off the removed ``MultiSpinProtocol`` shim)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import CellConfig, EngineBackend, MultiSpinCell, Request
from repro.configs import get_config
from repro.serving import SpecEngine


def _engine(target_arch="qwen2.5-3b", draft_arch="qwen2.5-3b", max_len=96):
    tcfg = get_config(target_arch).smoke()
    dcfg = get_config(draft_arch).smoke().replace(num_layers=1, d_model=32,
                                                  num_heads=2, num_kv_heads=1,
                                                  head_dim=16, d_ff=64)
    eng = SpecEngine(tcfg, dcfg, max_len=max_len)
    eng.init_params(jax.random.PRNGKey(0))
    return eng, tcfg, dcfg


def test_engine_rounds_commit_tokens():
    eng, tcfg, _ = _engine()
    B, M = 3, 10
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, M), 0, tcfg.vocab_size)
    state = eng.start(prompts)
    total = np.zeros(B, dtype=np.int64)
    for r in range(4):
        lengths = np.array([3, 5, 2])
        state, res, _ = eng.spin_round(state, lengths, jax.random.PRNGKey(10 + r))
        n = np.asarray(res.output_len)
        assert np.all(n >= 1) and np.all(n <= lengths + 1)
        total += n
    for b in range(B):
        assert len(state.committed[b]) == M + total[b]
    # positions advance exactly by committed counts
    np.testing.assert_array_equal(np.asarray(state.target_pos), M - 1 + total)


def test_engine_self_draft_accepts_everything():
    """Draft model == target model with no truncation => every draft token is
    accepted (ratio == 1) — the strongest end-to-end exactness check."""
    tcfg = get_config("qwen2.5-3b").smoke()
    eng = SpecEngine(tcfg, tcfg, max_len=96)
    kt, _ = jax.random.split(jax.random.PRNGKey(0))
    eng.t_params = eng.target.init(kt)
    eng.d_params = eng.t_params  # identical weights
    B, M, L = 2, 8, 4
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, M), 0, tcfg.vocab_size)
    state = eng.start(prompts)
    for r in range(3):
        state, res, _ = eng.spin_round(state, np.full(B, L),
                                       jax.random.PRNGKey(5 + r),
                                       vhat=tcfg.vocab_size)
        assert np.all(np.asarray(res.accept_counts) == L), \
            f"round {r}: {np.asarray(res.accept_counts)}"


@pytest.mark.parametrize("target_arch", ["mamba2-130m", "zamba2-2.7b"])
def test_engine_ssm_target_state_rollback(target_arch):
    """SSM/hybrid targets roll their recurrent state back to the accepted
    position.  Invariant: after any round, re-scoring the committed sequence
    from scratch must reproduce the engine's incremental next-token logits."""
    eng, tcfg, dcfg = _engine(target_arch=target_arch)
    B, M = 2, 8
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, M), 0, tcfg.vocab_size)
    state = eng.start(prompts)
    for r in range(2):
        state, res, _ = eng.spin_round(state, np.array([3, 4]),
                                       jax.random.PRNGKey(20 + r))
    # incremental: feed pending (== committed[-1], not yet in cache) against
    # the engine's rolled-back cache
    inc_logits, _ = eng.target.forward_window(
        eng.t_params, state.pending[:, None], eng.t_cache, state.target_pos)
    # fresh: full forward over the committed sequence, per row
    for b in range(B):
        assert state.committed[b][-1] == int(state.pending[b])
        seq = jnp.asarray(state.committed[b])[None, :]
        full, _ = eng.target.apply(eng.t_params, seq)
        np.testing.assert_allclose(np.asarray(inc_logits[b, 0]),
                                   np.asarray(full[0, -1]),
                                   rtol=2e-3, atol=2e-3)


def test_engine_attention_target_incremental_consistency():
    """Same invariant for attention targets (pointer-only rollback)."""
    eng, tcfg, _ = _engine(target_arch="phi4-mini-3.8b")
    B, M = 2, 8
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, M), 0, tcfg.vocab_size)
    state = eng.start(prompts)
    for r in range(3):
        state, res, _ = eng.spin_round(state, np.array([4, 2]),
                                       jax.random.PRNGKey(30 + r))
    inc_logits, _ = eng.target.forward_window(
        eng.t_params, state.pending[:, None], eng.t_cache, state.target_pos)
    for b in range(B):
        assert state.committed[b][-1] == int(state.pending[b])
        seq = jnp.asarray(state.committed[b])[None, :]
        full, _ = eng.target.apply(eng.t_params, seq)
        np.testing.assert_allclose(np.asarray(inc_logits[b, 0]),
                                   np.asarray(full[0, -1]),
                                   rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# Cell-level integration (the paper's full protocol loop over the engine)
# ---------------------------------------------------------------------------

def _cell(K=6, scheme="hete", backend=None, **cfg_kw):
    """A cell over the shim's legacy device mixture: persistent devices
    (never-retiring requests), heterogeneous alpha/T_S profiles."""
    rng = np.random.default_rng(0)
    cfg = CellConfig(scheme=scheme, t_ver_fix=0.03, t_ver_lin=0.002,
                     L_max=20, max_batch=K, seed=0, **cfg_kw)
    cell = MultiSpinCell(cfg, backend=backend, rng=rng)
    speeds = rng.uniform(0.85, 1.15, K)
    alphas = rng.choice([0.71, 0.74, 0.74, 0.86], K)
    for i in range(K):
        cell.submit(Request(rid=i, prompt_len=6, max_new_tokens=10 ** 12,
                            alpha=float(alphas[i]), T_S=0.03 * float(speeds[i]),
                            task=["squad", "gsm8k", "mtbench", "mbpp"][i % 4]))
    cell.admit()
    return cell


def test_cell_synthetic_rounds():
    out = _cell(K=8).run(30)
    assert out["tokens"] > 0
    assert out["goodput"] > 0
    # realized goodput within 30% of analytic prediction over 30 rounds
    assert abs(out["goodput"] - out["mean_predicted_goodput"]) \
        / out["mean_predicted_goodput"] < 0.3


def test_cell_scheme_ordering():
    results = {s: _cell(K=10, scheme=s).run(40)["goodput"]
               for s in ("hete", "homo", "uni-bw", "fixed")}
    assert results["hete"] >= 0.95 * results["homo"]
    assert results["hete"] >= 0.95 * results["fixed"]


def test_cell_estimator_converges():
    cell = _cell(K=6, use_estimator=True)
    cell.run(60)
    true_alpha = np.array([r.alpha for r in cell.scheduler.active])
    assert np.mean(np.abs(cell.estimator.alpha_hat - true_alpha)) < 0.12


def test_cell_checkpoint_restore():
    cell = _cell(K=5)
    cell.run(5)
    snap = cell.state_dict()
    cell2 = _cell(K=5)
    cell2.load_state_dict(snap)
    assert cell2._round_idx == 5
    np.testing.assert_allclose(cell2.channel.avg_gains,
                               cell.channel.avg_gains)


def test_cell_device_dropout_and_deadline():
    cell = _cell(K=8, deadline_factor=1.5)
    rec = cell.step()
    assert rec.active.sum() >= 1
    cell.leave(int(rec.rids[0]))
    rec2 = cell.step()
    assert len(rec2.lengths) == 7


def test_cell_with_real_engine():
    tcfg = get_config("qwen2.5-3b").smoke()
    dcfg = tcfg.replace(num_layers=1, d_model=32, num_heads=2, num_kv_heads=1,
                        head_dim=16, d_ff=64, name="draft-smoke")
    eng = SpecEngine(tcfg, dcfg, max_len=256)
    eng.init_params(jax.random.PRNGKey(0))
    K, M = 4, 6
    prompts = jax.random.randint(jax.random.PRNGKey(1), (K, M), 0, tcfg.vocab_size)
    backend = EngineBackend(eng, eng.start(prompts))
    cell = _cell(K=K, backend=backend)
    out = cell.run(4)
    assert out["tokens"] >= 4 * K  # >= 1 token per device per round
    assert all(len(c) > M for c in backend.state.committed)
