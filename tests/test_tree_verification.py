"""Token-tree multi-draft verification: packing, acceptance, and the engine.

Coverage per the tree-attention issue:
  * trie packing — prefix dedup, parent ordering, ancestor-mask closure
  * ``verify_tree`` at J=1 is BIT-IDENTICAL to ``verify_drafts`` (same rng
    stream, same outputs)
  * engine tree rounds at J=1 commit bit-identical tokens to the sequential
    path on BOTH cache layouts
  * J>1 engine rounds: committed text stays exact (incremental-consistency
    invariant through the cache-repair pass), dead-branch pages return to
    the pool every round
  * acceptance statistics match the ``multidraft`` scheme's max-of-J
    analytic model (the SyntheticBackend law) — exactly in the self-draft
    limit, to tolerance with a real draft model
  * the full cell serves ``multidraft`` on an ``EngineBackend`` with J >= 2
"""

import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.token_tree import DEAD, ROOT, build_token_tree
from repro.core.verification import truncate_renormalize, verify_drafts, verify_tree
from repro.serving import SpecEngine


def _engine(max_len=96, paged=False, num_pages=None, self_draft=False,
            tree_commit=None):
    tcfg = get_config("qwen2.5-3b").smoke()
    if self_draft:
        dcfg = tcfg.replace(name="draft-self")
    else:
        dcfg = tcfg.replace(
            num_layers=1,
            d_model=32,
            num_heads=2,
            num_kv_heads=1,
            head_dim=16,
            d_ff=64,
            name="draft-smoke",
        )
    kw = {}
    if paged:
        kw = {"cache_kind": "paged", "num_pages": num_pages or 96}
    if tree_commit is not None:
        kw["tree_commit"] = tree_commit
    eng = SpecEngine(tcfg, dcfg, max_len=max_len, **kw)
    eng.init_params(jax.random.PRNGKey(0))
    if self_draft:
        eng.d_params = eng.t_params
    return eng, tcfg


# ---------------------------------------------------------------------------
# trie packing
# ---------------------------------------------------------------------------


def test_build_token_tree_dedups_shared_prefixes():
    # two drafts sharing a 2-token prefix, one fully distinct
    tokens = np.array([[[5, 6, 7], [5, 6, 8], [9, 6, 7]]])
    probs = np.full((1, 3, 3), 0.5, np.float32)
    q_idx = np.zeros((1, 3, 3, 4), np.int32)
    q_val = np.zeros((1, 3, 3, 4), np.float32)
    tree = build_token_tree(tokens, probs, q_idx, q_val, np.array([3]))
    assert int(tree.n_nodes[0]) == 7  # 9 drafted positions, 2 deduped
    # drafts 0 and 1 share nodes at depth 1 and 2
    assert tree.paths[0, 0, 0] == tree.paths[0, 1, 0]
    assert tree.paths[0, 0, 1] == tree.paths[0, 1, 1]
    assert tree.paths[0, 0, 2] != tree.paths[0, 1, 2]
    assert tree.paths[0, 2, 0] != tree.paths[0, 0, 0]
    # parents precede children; roots carry ROOT, padding carries DEAD
    n = int(tree.n_nodes[0])
    for i in range(n):
        assert tree.parents[0, i] < i
    assert tree.parents[0, 0] == ROOT
    assert np.all(tree.parents[0, n:] == DEAD)
    assert np.all(tree.depth[0, :n] >= 1)


def test_window_mask_is_ancestor_closure():
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, 50, (2, 3, 4))
    probs = rng.uniform(0.1, 1.0, (2, 3, 4)).astype(np.float32)
    q_idx = np.zeros((2, 3, 4, 4), np.int32)
    q_val = np.zeros((2, 3, 4, 4), np.float32)
    tree = build_token_tree(tokens, probs, q_idx, q_val, np.array([4, 3]))
    mask = tree.window_mask()
    B, T, _ = mask.shape
    assert T == tree.width + 1
    for b in range(B):
        assert mask[b, 0, 0] and not mask[b, 0, 1:].any()
        for i in range(int(tree.n_nodes[b])):
            row = mask[b, i + 1]
            # expected: pending + self + transitive parents
            expect = np.zeros(T, bool)
            expect[0] = True
            j = i
            while j >= 0:
                expect[j + 1] = True
                j = int(tree.parents[b, j])
            np.testing.assert_array_equal(row, expect)


def test_chain_tree_mask_is_causal():
    tokens = np.arange(4).reshape(1, 1, 4)
    probs = np.full((1, 1, 4), 0.5, np.float32)
    q = np.zeros((1, 1, 4, 2))
    tree = build_token_tree(tokens, probs, q, q, np.array([4]))
    np.testing.assert_array_equal(tree.window_mask()[0], np.tril(np.ones((5, 5), bool)))
    np.testing.assert_array_equal(tree.window_depth()[0], np.arange(5))


# ---------------------------------------------------------------------------
# verify_tree == verify_drafts at J=1 (bit-identical rng stream)
# ---------------------------------------------------------------------------


def test_verify_tree_chain_bit_identical_to_sequential():
    B, L, V, vhat = 3, 4, 64, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    draft_tokens = jax.random.randint(ks[0], (B, L), 0, V)
    q_dense = jax.random.dirichlet(ks[1], jnp.ones((V,)) * 0.3, (B, L))
    q_idx, q_val = truncate_renormalize(q_dense, vhat)
    probs = jax.random.uniform(ks[2], (B, L), minval=0.05, maxval=1.0)
    logits = jax.random.normal(ks[3], (B, L + 1, V)) * 2.0
    draft_len = jnp.array([4, 2, 3])

    key = jax.random.PRNGKey(42)
    seq = verify_drafts(
        key,
        draft_tokens,
        probs,
        logits,
        q_idx=q_idx,
        q_val=q_val,
        draft_len=draft_len,
    )
    tree = build_token_tree(
        np.asarray(draft_tokens)[:, None, :],
        np.asarray(probs)[:, None, :],
        np.asarray(q_idx)[:, None],
        np.asarray(q_val)[:, None],
        np.asarray(draft_len),
    )
    got = verify_tree(
        key,
        jnp.asarray(tree.tokens),
        jnp.asarray(tree.parents),
        jnp.asarray(tree.depth),
        jnp.asarray(tree.probs),
        jnp.asarray(tree.paths),
        logits,
        jnp.asarray(tree.q_idx),
        jnp.asarray(tree.q_val),
        draft_len,
    )
    np.testing.assert_array_equal(np.asarray(got.accept_counts), np.asarray(seq.accept_counts))
    np.testing.assert_array_equal(np.asarray(got.output_tokens), np.asarray(seq.output_tokens))
    np.testing.assert_array_equal(np.asarray(got.output_len), np.asarray(seq.output_len))
    np.testing.assert_array_equal(np.asarray(got.accept_mask), np.asarray(seq.accept_mask))
    assert np.all(np.asarray(got.winner) == 0)


# ---------------------------------------------------------------------------
# engine: tree-vs-sequential equivalence at J=1
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("paged", [False, True])
def test_engine_tree_j1_commits_identical_tokens(paged):
    def run(tree):
        eng, tcfg = _engine(paged=paged)
        prompts = jax.random.randint(jax.random.PRNGKey(1), (3, 10), 0, tcfg.vocab_size)
        state = eng.start(prompts)
        for r in range(4):
            lengths = np.array([3, 5, 2])
            state, res, _ = eng.spin_round(state, lengths, jax.random.PRNGKey(10 + r), tree=tree)
        return [list(c) for c in state.committed]

    assert run(False) == run(True)


# ---------------------------------------------------------------------------
# engine: J > 1 tree rounds
# ---------------------------------------------------------------------------


def test_engine_multidraft_rounds_stay_exact():
    """After tree rounds, the repaired cache must reproduce from-scratch
    logits for the committed sequence (the rollback invariant of the
    sequential engine, now across divergent branches)."""
    eng, tcfg = _engine(paged=True, num_pages=96)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, tcfg.vocab_size)
    state = eng.start(prompts)
    lengths = np.array([4, 3])
    for r in range(3):
        state, res, _ = eng.spin_round(state, lengths, jax.random.PRNGKey(77 + r), draft_width=3)
        n = np.asarray(res.output_len)
        assert np.all(n >= 1) and np.all(n <= lengths + 1)
    cache = dict(eng.t_cache, pages=jnp.asarray(eng.t_pages.page_table(range(2))))
    pend = state.pending[:, None]
    inc, _ = eng.target.forward_window(eng.t_params, pend, cache, state.target_pos)
    for b in range(2):
        assert state.committed[b][-1] == int(state.pending[b])
        seq = jnp.asarray(state.committed[b])[None, :]
        full, _ = eng.target.apply(eng.t_params, seq)
        np.testing.assert_allclose(
            np.asarray(inc[b, 0]),
            np.asarray(full[0, -1]),
            rtol=2e-3,
            atol=2e-3,
        )


def test_engine_multidraft_returns_dead_branch_pages():
    eng, tcfg = _engine(paged=True, num_pages=96)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, tcfg.vocab_size)
    state = eng.start(prompts)
    for r in range(3):
        key = jax.random.PRNGKey(5 + r)
        state, res, _ = eng.spin_round(state, np.array([4, 4]), key, draft_width=3)
        # after the round, mapped pages cover exactly the accepted prefixes
        for b in range(2):
            tp = int(np.asarray(state.target_pos)[b])
            assert eng.t_pages.length(b) == tp
            assert len(eng.t_pages._tables[b]) == eng.t_pages.pages_for(tp)
    eng.t_pages.check_invariants()
    eng.d_pages.check_invariants()


def test_engine_selfdraft_multidraft_accepts_everything():
    """Draft == target with no truncation: every tree node is accepted, so
    output_len == L + 1 every round — exactly the SyntheticBackend law at
    alpha = 1 (deterministic acceptance-statistics parity)."""
    eng, tcfg = _engine(max_len=128, self_draft=True)
    B, M, L = 2, 8, 3
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, M), 0, tcfg.vocab_size)
    state = eng.start(prompts)
    for r in range(3):
        state, res, _ = eng.spin_round(
            state,
            np.full(B, L),
            jax.random.PRNGKey(5 + r),
            vhat=tcfg.vocab_size,
            draft_width=2,
        )
        assert np.all(np.asarray(res.output_len) == L + 1)


def test_multidraft_acceptance_statistics_match_analytic():
    """Mean committed tokens per round must track the multidraft scheme's
    max-of-J model  E[N] = 1 + sum_l (1 - (1 - a^l)^J)  at the engine's own
    measured per-node acceptance rate a (loose band: the model assumes
    position-independent acceptance and independent runs; the trie shares
    prefix outcomes, which can only lower the engine mean slightly)."""
    eng, tcfg = _engine(max_len=160, paged=True, num_pages=120)
    B, L, J, rounds = 3, 4, 3, 12
    prompts = jax.random.randint(jax.random.PRNGKey(2), (B, 8), 0, tcfg.vocab_size)
    state = eng.start(prompts)
    accepts, valids, lens = [], [], []
    for r in range(rounds):
        key = jax.random.PRNGKey(100 + r)
        state, res, _ = eng.spin_round(state, np.full(B, L), key, draft_width=J)
        accepts.append(np.asarray(res.accept_mask))
        valids.append(np.asarray(res.node_valid))
        lens.append(np.asarray(res.output_len))
    acc = np.concatenate(accepts).ravel()
    val = np.concatenate(valids).ravel()
    alpha_hat = acc[val].mean()
    ls = np.arange(1, L + 1)
    expect = 1.0 + np.sum(1.0 - (1.0 - alpha_hat**ls) ** J)
    measured = float(np.concatenate(lens).mean())
    assert abs(measured - expect) / expect < 0.30, (measured, expect, alpha_hat)


# ---------------------------------------------------------------------------
# cell integration: the multidraft scheme SERVED on an EngineBackend
# ---------------------------------------------------------------------------


def test_cell_multidraft_on_engine_backend():
    from repro.api import CellConfig, EngineBackend, MultiSpinCell, Request

    eng, tcfg = _engine(max_len=160, paged=True, num_pages=2 * 3 * 10)
    K = 3
    prompts = jax.random.randint(jax.random.PRNGKey(1), (K, 8), 0, tcfg.vocab_size)
    backend = EngineBackend(eng, eng.start(prompts))
    cfg = CellConfig(
        scheme="multidraft",
        scheme_params={"J_min": 2, "J_max": 3},
        max_batch=K,
        L_max=5,
        seed=0,
    )
    cell = MultiSpinCell(cfg, backend=backend)
    rng = np.random.default_rng(0)
    for i in range(K):
        cell.submit(
            Request(
                rid=i,
                prompt_len=8,
                max_new_tokens=10**9,
                alpha=float(rng.choice([0.71, 0.86])),
                T_S=0.009,
            )
        )
    out = cell.run(4)
    assert out["tokens"] >= 4 * K  # >= 1 committed token per device per round
    assert all(rec.draft_width >= 2 for rec in cell.history)
    eng.t_pages.check_invariants()
    eng.d_pages.check_invariants()


# ---------------------------------------------------------------------------
# engine: scatter-commit vs cache-repair forward
# ---------------------------------------------------------------------------


def _scatter_commit_parity(paged):
    """Assert scatter-commit vs repair-forward parity (see the test below)."""
    from repro.models.layers import gather_kv_window

    def run(commit):
        eng, tcfg = _engine(paged=paged, self_draft=True, tree_commit=commit)
        prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0,
                                     tcfg.vocab_size)
        state = eng.start(prompts)
        accepted = 0
        for r in range(4):
            state, res, _ = eng.spin_round(state, np.array([3, 3]),
                                           jax.random.PRNGKey(100 + r),
                                           vhat=16, draft_width=2)
            accepted += int(np.asarray(res.accept_counts).sum())
        return eng, state, accepted

    eng_r, st_r, acc_r = run("repair")
    eng_s, st_s, acc_s = run("scatter")
    assert acc_r == acc_s
    assert acc_r > 0, "test is vacuous without acceptances"
    assert [list(c) for c in st_r.committed] == [list(c) for c in st_s.committed]
    np.testing.assert_array_equal(np.asarray(st_r.target_pos),
                                  np.asarray(st_s.target_pos))
    # live cache slots (positions < fill level) must match; slots beyond the
    # fill level are dead — repair rewrites them, scatter leaves stale tree
    # rows, and causal masking means neither is ever read.
    for eng, attr, pos in ((None, "t_cache", st_r.target_pos),
                           (None, "d_cache", st_r.draft_pos)):
        pos = np.asarray(pos)
        grid = jnp.arange(int(pos.max()))[None, :].repeat(2, 0)
        for er, es in ((eng_r, eng_s),):
            cr, cs = getattr(er, attr), getattr(es, attr)
            pages_r = pages_s = None
            if paged:
                pg = "t_pages" if attr == "t_cache" else "d_pages"
                pages_r = jnp.asarray(getattr(er, pg).page_table(range(2)))
                pages_s = jnp.asarray(getattr(es, pg).page_table(range(2)))
                np.testing.assert_array_equal(np.asarray(pages_r),
                                              np.asarray(pages_s))
            for leaf in ("k", "v", "dense_k", "dense_v"):
                if leaf not in cr:
                    continue
                wr = np.asarray(gather_kv_window(cr[leaf], grid, pages_r),
                                np.float32)
                ws = np.asarray(gather_kv_window(cs[leaf], grid, pages_s),
                                np.float32)
                live = (np.arange(grid.shape[1])[None, :]
                        < pos[:, None])          # (B, S)
                d = np.abs(wr - ws) * live[None, :, :, None, None]
                assert d.max() < 1e-4, (attr, leaf, d.max())


@pytest.mark.parametrize("paged", [False, True])
def test_engine_scatter_commit_matches_repair(paged):
    """The default scatter-commit (winning branch's K/V scattered from the
    tree window) must commit the SAME tokens as the repair-forward path and
    leave the same live cache contents, round after round, at the same seed.

    Self-draft with vhat << vocab gives a mix of acceptances and rejections,
    so the scatter path (including dead-branch shadowing) is exercised.

    Runs in a fresh subprocess: compiling the two extra self-draft engines
    late in a long-lived pytest process segfaults the XLA CPU compiler
    (accumulated compile state — jaxlib bug, reproducible in any mode), while
    a clean process compiles and passes in under two minutes.
    """
    root = Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [str(root / "src")] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else [])
    )
    res = subprocess.run(
        [sys.executable, str(Path(__file__).resolve()), "scatter-parity", "paged" if paged else "contiguous"],
        env=env,
        cwd=root,
        capture_output=True,
        text=True,
        timeout=900,
    )
    assert res.returncode == 0, f"scatter-parity subprocess failed:\n{res.stdout}\n{res.stderr}"


if __name__ == "__main__":
    # subprocess entry point for test_engine_scatter_commit_matches_repair
    assert sys.argv[1] == "scatter-parity"
    _scatter_commit_parity(paged=sys.argv[2] == "paged")
