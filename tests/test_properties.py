"""Hypothesis property tests on system-level invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed (pip install '.[test]'); "
           "property tests skipped")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.bandwidth import solve_equalized_phi
from repro.core.goodput import expected_accepted_tokens
from repro.core.verification import verify_drafts
from repro.training.optimizer import OptimizerConfig, apply_gradients, init_optimizer


# ---------------------------------------------------------------------------
# Verification invariants over random shapes/dists
# ---------------------------------------------------------------------------

@given(st.integers(1, 6), st.integers(1, 8), st.integers(2, 24),
       st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_verify_output_structure(B, L, V, seed):
    """For ANY inputs: outputs are draft-prefix + one extra token; counts in
    range; padding zeros beyond n+1."""
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    p = jax.random.dirichlet(keys[0], jnp.ones((V,)), (B, L + 1))
    q = jax.random.dirichlet(keys[1], jnp.ones((V,)), (B, L))
    toks = jax.random.categorical(keys[2], jnp.log(q), axis=-1).astype(jnp.int32)
    probs = jnp.take_along_axis(q, toks[..., None], -1)[..., 0]
    res = verify_drafts(keys[3], toks, probs, jnp.log(jnp.maximum(p, 1e-30)),
                        q_dense=q)
    n = np.asarray(res.accept_counts)
    out = np.asarray(res.output_tokens)
    toks_np = np.asarray(toks)
    assert np.all((0 <= n) & (n <= L))
    for b in range(B):
        # accepted prefix is copied verbatim from the draft
        np.testing.assert_array_equal(out[b, :n[b]], toks_np[b, :n[b]])
        # position n holds the extra token (valid vocab id)
        assert 0 <= out[b, n[b]] < V
        # padding after n+1 is zero
        assert np.all(out[b, n[b] + 1:] == 0)


@given(st.integers(2, 16), st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_expected_tokens_monotone_in_length(K, seed):
    """E[N|L] strictly increases with L for any alpha in (0,1)."""
    rng = np.random.default_rng(seed)
    alpha = rng.uniform(0.05, 0.99)
    vals = [float(expected_accepted_tokens(alpha, L)) for L in range(1, K + 1)]
    assert all(b > a for a, b in zip(vals, vals[1:]))
    assert all(v <= 1.0 / (1.0 - alpha) + 1e-9 for v in vals)  # geometric cap


@given(st.integers(2, 12), st.integers(0, 500))
@settings(max_examples=30, deadline=None)
def test_lemma3_bandwidth_positive_and_feasible(K, seed):
    rng = np.random.default_rng(seed)
    L = rng.integers(1, 25, K).astype(float)
    T_S = rng.uniform(0.002, 0.05, K)
    r = rng.uniform(1.0, 9.0, K)
    B = rng.uniform(0.5e6, 40e6)
    phi, Bk = solve_equalized_phi(L, T_S, r, 31744.0, B)
    assert np.all(Bk > 0)
    np.testing.assert_allclose(np.sum(Bk), B, rtol=1e-8)
    assert phi > np.max(L * T_S)


# ---------------------------------------------------------------------------
# Optimizer invariants
# ---------------------------------------------------------------------------

@given(st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_adamw_step_is_finite_and_bounded(seed):
    """One AdamW step never produces NaN and respects the clip+lr bound."""
    rng = np.random.default_rng(seed)
    cfg = OptimizerConfig(learning_rate=1e-2, warmup_steps=0, weight_decay=0.0)
    params = {"w": jnp.asarray(rng.normal(size=(16,)), jnp.float32)}
    grads = {"w": jnp.asarray(rng.normal(size=(16,)) * 10 ** rng.uniform(-3, 6),
                              jnp.float32)}
    state = init_optimizer(cfg, params)
    new_params, new_state, m = apply_gradients(cfg, params, grads, state)
    assert bool(jnp.isfinite(new_params["w"]).all())
    # |update| <= lr * (|m_hat / (sqrt(v_hat)+eps)|) ~ lr big-O bound
    delta = np.abs(np.asarray(new_params["w"] - params["w"]))
    assert delta.max() < cfg.learning_rate * 50
